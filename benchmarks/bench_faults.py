"""Fault-tolerance suite: the recovery invariant and its overhead, gated.

Two claims the perf gate watches (``CHECK_METRICS["faults"]``):

* ``faults_recovery.identical_to_inline`` — a chaos schedule (worker crash
  + corrupted result pickle, deterministic seeds) thrown at the hardened
  subprocess backend recovers results bit-identical to the inline
  reference.  A flip to False is the robustness layer silently changing
  semantics — the one thing it must never do.
* ``faults_overhead.overhead_ratio`` — the supervision machinery
  (fault-plan consultation, retry bookkeeping, shard supervision) with NO
  faults injected, measured against a bare launch of the identical shard
  set with none of that machinery.  Target: indistinguishable (< 2%
  overhead on the median); the gate catches the ratio regressing.

Trial sizes are chosen so worker startup does not drown the signal but the
suite stays CI-sized.
"""

from __future__ import annotations

import statistics
import time
from typing import List

from repro.api import (DesignSpec, ExperimentSpec, FaultSpec, Row, TrialSpec,
                       WorkloadSpec, run_experiment)

N_KEYS = 30_000
QUERIES = 1500
SESSIONS = ((0.05, 0.85, 0.05, 0.05),)
REPS = 5     # overhead legs: median over REPS runs per path

SPEC = ExperimentSpec(
    name="faults",
    workload=WorkloadSpec(indices=(4, 7, 9, 11), rhos=(), nominal=True),
    design=DesignSpec(fixed=(6.0, 4.0, 1.0)),   # no tuning: engine-only
    trial=TrialSpec(n_keys=N_KEYS, n_queries=QUERIES, sessions=SESSIONS,
                    key_space=2 ** 24, per_workload_keys=True, key_seed=11),
    system=(("N", float(N_KEYS)), ("entry_bits", 64.0 * 8),
            ("bits_per_entry", 6.0), ("min_buf_bits", 64.0 * 8 * 64),
            ("max_T", 20.0)),
)

CHAOS = (FaultSpec(kind="crash", shards=(0,), max_hits=1, seed=0),
         FaultSpec(kind="corrupt", shards=(1,), max_hits=1, seed=0))


def _identical(a, b) -> bool:
    if set(a.fleet) != set(b.fleet) or a.failed_cells or b.failed_cells:
        return False
    return all(x.io == y.io
               for key in a.fleet
               for x, y in zip(a.fleet[key], b.fleet[key])) \
        and all(a.probes[k] == b.probes[k] for k in a.fleet)


def _bare_wall(backend, plan) -> float:
    """The machinery-free reference: the same shard partition launched
    directly (no fault plan, no retry loop, no supervisor, no persistence)
    — what the pre-hardening backend did."""
    import concurrent.futures
    import os
    import pickle
    import subprocess
    import sys
    shards = backend._partition(plan)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(p for p in sys.path if p)
    cmd = [sys.executable, "-c",
           "from repro.api.backends import _worker_main; _worker_main()"]

    def launch(shard):
        job = pickle.dumps((plan, [plan.trees[t] for t in shard]),
                           protocol=pickle.HIGHEST_PROTOCOL)
        proc = subprocess.run(cmd, input=job, stdout=subprocess.PIPE,
                              env=env, check=True)
        return pickle.loads(proc.stdout)

    t0 = time.time()
    with concurrent.futures.ThreadPoolExecutor(len(shards)) as pool:
        list(pool.map(launch, shards))
    return time.time() - t0


def run() -> List[Row]:
    from repro.api import compile_spec, get_backend
    rows: List[Row] = []

    # -- leg 1: recovery fidelity under chaos -------------------------------
    inline = run_experiment(SPEC)
    chaos_spec = ExperimentSpec.from_json(
        SPEC.to_json())  # chaos scenario round-trips like any spec
    import dataclasses
    chaos_spec = dataclasses.replace(
        chaos_spec, backend="subprocess",
        backend_params=(("workers", 2), ("max_retries", 2),
                        ("timeout_s", 300.0)),
        faults=CHAOS)
    t0 = time.time()
    chaos = run_experiment(chaos_spec)
    chaos_s = time.time() - t0
    rows.append(Row(
        "faults_recovery", chaos_s * 1e6,
        identical_to_inline=_identical(inline, chaos),
        injected=len(CHAOS), shard_retries=int(chaos.walls["shard_retries"]),
        shards_run=int(chaos.walls["shards_run"]),
        failed_trees=int(chaos.walls["failed_trees"]),
        trees=len(chaos.fleet), n_keys=N_KEYS, n_queries=QUERIES,
    ))

    # -- leg 2: machinery overhead with faults disabled ---------------------
    cx = compile_spec(SPEC)
    solved = {d: get_backend("inline", ()).solve(p)
              for d, p in cx.tuning_plans().items()}
    backend = get_backend("subprocess", (("workers", 2),))
    plan = cx.build_trial(cx.select_arms(solved))
    supervised, bare = [], []
    for _ in range(REPS):
        report = cx.select_arms(solved)
        t0 = time.time()
        backend.run_trial(plan, report)      # empty fault plan, full path
        supervised.append(time.time() - t0)
        bare.append(_bare_wall(backend, plan))
    sup_s = statistics.median(supervised)
    bare_s = statistics.median(bare)
    rows.append(Row(
        "faults_overhead", sup_s * 1e6,
        overhead_ratio=round(sup_s / bare_s, 4),
        overhead_pct=round((sup_s / bare_s - 1.0) * 100.0, 2),
        supervised_s=round(sup_s, 3), bare_s=round(bare_s, 3),
        reps=REPS, workers=2, trees=len(plan.trees),
    ))
    return rows
