# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure (+ roofline).

Usage:
    PYTHONPATH=src python -m benchmarks.run                  # all
    PYTHONPATH=src python -m benchmarks.run fig6 tab5        # substring filter
    PYTHONPATH=src python -m benchmarks.run --json out/      # + BENCH_*.json
    PYTHONPATH=src python -m benchmarks.run --check tuner tab5   # perf gate
    PYTHONPATH=src python -m benchmarks.run --spec exp.json  # run any spec

``--json OUT`` writes one ``BENCH_<suite>.json`` per executed suite into the
OUT directory: per-suite wall time plus every row's derived metrics, so later
PRs have a machine-readable perf trajectory to compare against.

``--spec FILE.json`` runs an arbitrary :class:`repro.api.ExperimentSpec`
(the declarative experiment facade) end-to-end and emits its report in the
same CSV/BENCH-json formats — new scenarios need a JSON file, not a new
bench script.  The spec's ``name`` becomes the suite name.

``--spec`` composes with the crash-safe sweep substrate: ``--run-dir DIR``
hands the subprocess backend a directory to persist per-shard results into
(atomic, checksummed), and ``--resume`` re-runs a killed sweep executing
only the shards that never completed (``docs/faults.md``).

``--check`` re-runs the selected suites and diffs the measured perf
trajectory against the committed ``BENCH_<suite>.json`` baselines
(``--baseline DIR``, default the repo root): per-suite wall time plus the
curated directional metrics in ``CHECK_METRICS`` must stay within
``--tolerance`` (default 1.5x slack for machine noise) of the baseline.
Exit codes are distinct so CI can tell the failure modes apart: 1 for a
perf regression (or a crashed suite), 2 for a *misconfigured* gate — a
checked suite with no committed baseline (a new suite must commit its
``BENCH_<suite>.json`` before the gate can watch it), a committed baseline
that fails checksum validation (torn, tampered, or hand-edited — a corrupt
reference must read as "fix the baseline", never as a phantom regression),
one that parses as JSON but lacks the suite's ``CHECK_METRICS`` rows/keys
(e.g. stale, or committed before a metric was added), or a filter that
selects no suite at all (a typo would otherwise pass vacuously).

``--list`` prints the suite names one per line, each with the one-line
description from its bench module's docstring (parsed via ``ast`` — no
jax import), and exits; ``--list --gated`` prints only the suites the
perf gate watches (the ``CHECK_METRICS`` keys) as *bare* names, so CI
derives its gate list from here instead of hardcoding it.
"""

import argparse
import json
import os
import time
import traceback

# suite -> {"row_name.metric": "lower"|"higher"} perf metrics the --check
# gate enforces in addition to every suite's wall_time_s ("lower").
CHECK_METRICS = {
    "tuner": {
        "perf_tuner_fig6_grid.batched_s": "lower",
        "perf_tuner_throughput.tunings_per_sec": "higher",
    },
    "tab5": {
        "tab5_fleet.engine_s": "lower",
    },
    "compaction": {
        "compaction_fleet.engine_s": "lower",
    },
    "api": {
        "api_fleet.engine_s": "lower",
    },
    "online": {
        "online_fleet.engine_s": "lower",
        "online_summary.online_recovery_min": "higher",
        # bool (int subclass): flipping to False reads as 0 < 1/tol
        "online_summary.claim_online_ge_robust_ge_stale": "higher",
    },
    "faults": {
        # bool: recovered-under-chaos results bit-identical to inline
        "faults_recovery.identical_to_inline": "higher",
        # supervised no-fault path vs raw path: must stay near 1.0
        "faults_overhead.overhead_ratio": "lower",
    },
    "kernels": {
        # fused data plane must stay faster than its jnp references
        "kernels_point_read.speedup_fused_vs_ref": "higher",
        "kernels_dual_solve.speedup_fused_vs_ref": "higher",
    },
    "roofline": {
        # the roofline table must keep measuring real kernel cells —
        # an all-empty run raises, and a shrinking cell count gates
        "roofline_kernels.measured_cells": "higher",
    },
    "memory": {
        "memory_fleet.engine_s": "lower",
        # arbitrated fleet throughput over the static equal split
        "memory_summary.fleet_speedup_min": "higher",
        # bools: arbitration never loses; disabled stays bit-identical
        "memory_summary.claim_arbitrated_ge_static": "higher",
        "memory_summary.claim_disabled_identical": "higher",
    },
    "scenarios": {
        "scenarios_fleet.engine_s": "lower",
        # bools: the robust hedge survives every named stress pattern,
        # and every adversary window's realized model cost stays under
        # the independently-solved KL dual bound (Eq. 13, measured live)
        "scenarios_summary.claim_robust_ge_stale": "higher",
        "scenarios_summary.claim_regret_le_dual_bound": "higher",
    },
    "obs": {
        "obs_fleet.engine_s": "lower",
        # enabled-vs-disabled telemetry tax on the same fleet (<= 1.05
        # gated in the suite itself; the baseline watches for creep)
        "obs_overhead.overhead_ratio": "lower",
        # bools: tracing never perturbs engine results; the measured-IO
        # calibration fit is at least as close as the hand constants
        "obs_identity.claim_bit_identical": "higher",
        "obs_calibration.claim_fit_ge_hand": "higher",
    },
}

#: --check exit codes: regression vs misconfiguration (missing baseline /
#: filters matching nothing) — CI treats both as failures but reports them
#: differently.
EXIT_REGRESSION = 1
EXIT_MISCONFIGURED = 2

#: suite key -> module name, kept static so ``--list`` (and filter
#: validation) need no jax import; modules are imported only when run.
SUITE_MODULES = [
    ("fig4", "bench_nominal_designs"),
    ("fig6", "bench_robust_vs_nominal"),
    ("fig7_8", "bench_rho_impact"),
    ("fig9", "bench_rho_choice"),
    ("fig10", "bench_entry_size"),
    ("tab5", "bench_system_eval"),
    ("fig19", "bench_flexible_robustness"),
    ("tuner", "bench_tuner_perf"),
    ("kernels", "bench_kernels"),
    ("roofline", "bench_roofline"),
    ("robust_sharding", "bench_robust_sharding"),
    ("compaction", "bench_compaction_space"),
    ("api", "bench_api"),
    ("online", "bench_online_drift"),
    ("faults", "bench_faults"),
    ("memory", "bench_memory_fleet"),
    ("scenarios", "bench_scenarios"),
    ("obs", "bench_obs"),
]


def _suite_description(module_name: str) -> str:
    """First docstring line of a bench module, parsed via ``ast`` so
    ``--list`` stays jax-import-free (module import pulls in the stack)."""
    import ast
    path = os.path.join(os.path.dirname(__file__), module_name + ".py")
    try:
        with open(path, encoding="utf-8") as f:
            doc = ast.get_docstring(ast.parse(f.read()))
    except (OSError, SyntaxError):
        doc = None
    return doc.strip().splitlines()[0] if doc else ""


def _load_baselines(suites, baseline_dir):
    """Snapshot every baseline BEFORE any suite runs (or --json rewrites
    them): with OUT == baseline dir the gate would otherwise compare each
    fresh BENCH_<suite>.json against itself and pass vacuously.

    Returns ``(baselines, invalid)``: baselines that exist but are torn
    (unparseable JSON), unchecksummed, or checksum-invalid land in
    ``invalid`` — the caller exits EXIT_MISCONFIGURED for those, because
    diffing against a corrupt reference would report phantom regressions
    (or worse, vacuously pass)."""
    from repro.faults import CHECKSUM_KEY, checksum_ok
    out, invalid = {}, []
    for key, _ in suites:
        path = os.path.join(baseline_dir, f"BENCH_{key}.json")
        if not os.path.exists(path):
            continue
        try:
            with open(path) as f:
                base = json.load(f)
        except (json.JSONDecodeError, UnicodeDecodeError) as exc:
            invalid.append(f"BENCH_{key}.json: unparseable "
                           f"(torn write? {exc})")
            continue
        if not isinstance(base, dict) or CHECKSUM_KEY not in base:
            invalid.append(f"BENCH_{key}.json: no '{CHECKSUM_KEY}' field "
                           "(regenerate with --json and commit)")
            continue
        if not checksum_ok(base):
            invalid.append(f"BENCH_{key}.json: checksum mismatch "
                           "(corrupt, truncated, or hand-edited baseline)")
            continue
        out[key] = base
    return out, invalid


def _check_suite(key, rows, wall, base, tol):
    """Compare one executed suite against its committed baseline.

    Returns ``(regressions, misconfigured)`` — two lists of human-readable
    strings (both empty = pass).  A *misconfigured* gate (a committed
    baseline that parses as JSON but is not the BENCH schema, or is missing
    the CHECK_METRICS rows/keys for its suite — e.g. a stale baseline
    committed before a metric was added) is reported separately so the
    caller exits EXIT_MISCONFIGURED instead of crashing or reporting a
    phantom regression; a metric missing from the *run* is a real
    regression (the suite stopped producing it)."""
    regressions = []
    misconfigured = []
    if not isinstance(base, dict):
        return [], [f"BENCH_{key}.json: baseline is "
                    f"{type(base).__name__}, not a BENCH schema object"]

    def compare(label, measured, reference, direction, slack=1.0):
        if not isinstance(measured, (int, float)) or \
                not isinstance(reference, (int, float)) or reference <= 0:
            return
        ratio = measured / reference
        t = tol * slack
        bad = ratio > t if direction == "lower" else ratio < 1.0 / t
        status = "REGRESSION" if bad else "ok"
        print(f"# check {label}: {measured:.4g} vs baseline "
              f"{reference:.4g} ({direction} is better) [{status}]")
        if bad:
            regressions.append(f"{label}: {measured:.4g} vs {reference:.4g}")

    # wall time gates at double slack: absolute seconds vary with the host
    # (laptop vs CI runner, cold jit caches); the curated relative metrics
    # below are the primary signal
    compare(f"{key}.wall_time_s", wall, base.get("wall_time_s"), "lower",
            slack=2.0)
    derived_by_row = {r.name: r.derived for r in rows}
    base_rows = base.get("rows")
    if not isinstance(base_rows, list):
        base_rows = []
        misconfigured.append(f"BENCH_{key}.json: no 'rows' list")
    base_by_row = {r["name"]: r.get("derived") or {}
                   for r in base_rows
                   if isinstance(r, dict) and "name" in r}
    for spec, direction in CHECK_METRICS.get(key, {}).items():
        row_name, metric = spec.rsplit(".", 1)
        measured = derived_by_row.get(row_name, {}).get(metric)
        reference = base_by_row.get(row_name, {}).get(metric)
        if reference is None:
            misconfigured.append(
                f"{spec}: missing from BENCH_{key}.json (regenerate the "
                "baseline with --json and commit it)")
            continue
        if measured is None:
            regressions.append(f"{spec}: missing (run)")
            continue
        compare(spec, float(measured), float(reference), direction)
    return regressions, misconfigured


def _jsonable(x):
    """Strict-JSON coercion; one implementation, in the report module."""
    from repro.api.report import jsonable
    return jsonable(x)


def _run_spec(args) -> None:
    """``--spec FILE.json``: run one declarative experiment end-to-end.

    ``--run-dir`` / ``--resume`` override the subprocess backend's
    persistence knobs (CLI wins over ``backend_params`` so one committed
    spec file serves both fresh runs and resumes)."""
    from repro.api import ExperimentSpec, get_backend, run_experiment
    with open(args.spec) as f:
        spec = ExperimentSpec.from_json(f.read())
    backend = None
    if args.run_dir or args.resume:
        params = dict(spec.backend_params)
        params["run_dir"] = args.run_dir
        params["resume"] = args.resume
        backend = get_backend(spec.backend, tuple(params.items()))
    print(f"# spec {args.spec!r} -> experiment {spec.name!r} "
          f"(backend={spec.backend}"
          + (f", run_dir={args.run_dir!r}" if args.run_dir else "")
          + (", resume" if args.resume else "") + ")", flush=True)
    print("name,us_per_call,derived")
    report = run_experiment(spec, backend=backend)
    rows = report.rows()
    for row in rows:
        print(row.csv(), flush=True)
    recovery = {k: int(v) for k, v in report.walls.items()
                if k in ("resumed_trees", "shards_run", "shard_retries",
                         "reshard_trees", "failed_trees")}
    if recovery:
        print("# recovery: " + " ".join(f"{k}={v}"
                                        for k, v in sorted(recovery.items())),
              flush=True)
    for (cell, pol), err in sorted(report.failed_cells.items(),
                                   key=lambda kv: str(kv[0])):
        print(f"# WARNING unrecovered cell {cell} arm {pol!r}: "
              + (err.splitlines()[-1][:200] if err else "?"), flush=True)
    print(f"# {spec.name} done in {report.wall_time_s:.1f}s", flush=True)
    if args.trace:
        from repro import obs
        from repro.faults import atomic_write_json
        from repro.obs.trace import write_trace
        n = write_trace(os.path.join(args.trace,
                                     f"trace_{spec.name}.json"))
        atomic_write_json(os.path.join(args.trace,
                                       f"metrics_{spec.name}.json"),
                          _jsonable(obs.metrics_snapshot()))
        print(f"# trace {spec.name}: {n} events -> "
              f"{args.trace}/trace_{spec.name}.json", flush=True)
    if args.json:
        os.makedirs(args.json, exist_ok=True)
        path = os.path.join(args.json, f"BENCH_{spec.name}.json")
        report.write_bench_json(path, rows)
        print(f"# wrote {path}", flush=True)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("filters", nargs="*",
                        help="substring filters on suite names")
    parser.add_argument("--json", metavar="OUT", default=None,
                        help="directory to write per-suite BENCH_<suite>.json")
    parser.add_argument("--check", action="store_true",
                        help="diff measured perf against committed baselines; "
                             "exit 1 on regression, 2 on a missing baseline "
                             "or a filter matching no suite")
    parser.add_argument("--list", action="store_true",
                        help="print the available suite names and exit")
    parser.add_argument("--gated", action="store_true",
                        help="with --list: print only the perf-gated suites "
                             "(CHECK_METRICS keys)")
    parser.add_argument("--spec", metavar="FILE.json", default=None,
                        help="run one declarative repro.api.ExperimentSpec "
                             "and emit its report (honors --json)")
    parser.add_argument("--trace", metavar="DIR", default=None,
                        help="enable structured telemetry (repro.obs) and "
                             "write per-suite trace_<suite>.json (Chrome/"
                             "Perfetto) + metrics_<suite>.json into DIR; "
                             "off by default and guaranteed not to change "
                             "any measured result")
    parser.add_argument("--run-dir", metavar="DIR", default=None,
                        help="with --spec: persist per-shard results into "
                             "DIR (atomic, checksummed) as they complete")
    parser.add_argument("--resume", action="store_true",
                        help="with --spec --run-dir: reuse valid persisted "
                             "shard results, execute only the remainder")
    parser.add_argument("--baseline", metavar="DIR",
                        default=os.path.join(os.path.dirname(__file__), ".."),
                        help="baseline directory for --check "
                             "(default: repo root)")
    parser.add_argument("--tolerance", type=float, default=1.5,
                        help="--check slack factor on every metric "
                             "(default 1.5x)")
    args = parser.parse_args()

    if args.list:
        if args.gated:
            # bare names, one per line: CI job matrices parse this output,
            # so it must stay byte-stable as suites gain descriptions
            for key, _ in SUITE_MODULES:
                if key in CHECK_METRICS:
                    print(key)
            return
        width = max(len(key) for key, _ in SUITE_MODULES)
        for key, name in SUITE_MODULES:
            print(f"{key:<{width}}  {_suite_description(name)}".rstrip())
        print()
        print("# --trace DIR: any suite above also emits trace_<suite>.json"
              " (open in Perfetto / chrome://tracing) and"
              " metrics_<suite>.json; see docs/observability.md")
        return
    if args.trace:
        # One switch flips the whole stack: the instrumented seams all go
        # through the repro.obs process-global, and bench modules that
        # emit artifacts (bench_obs's calibration) look for REPRO_OBS_OUT.
        os.makedirs(args.trace, exist_ok=True)
        os.environ["REPRO_OBS_OUT"] = args.trace
        from repro import obs
        obs.configure(enabled=True, clock="wall")
    if args.resume and not args.run_dir:
        parser.error("--resume requires --run-dir (the directory holding "
                     "the persisted shard results)")
    if (args.run_dir or args.resume) and not args.spec:
        parser.error("--run-dir/--resume only apply to --spec runs")
    if args.spec:
        if args.check:
            parser.error("--spec and --check are mutually exclusive: the "
                         "gate runs registered suites against committed "
                         "baselines; to gate a spec-driven experiment, add "
                         "it as a suite with a CHECK_METRICS entry")
        _run_spec(args)
        return
    selected_names = [(key, name) for key, name in SUITE_MODULES
                      if not args.filters or any(f in key for f in
                                                 args.filters)]
    if not selected_names:
        print(f"error: filters {args.filters} match no suite; "
              "run --list to see suite names")
        raise SystemExit(EXIT_MISCONFIGURED)
    import importlib
    # `python -m benchmarks.run` imports siblings relatively; a direct
    # `python benchmarks/run.py` has no package, but the script's own
    # directory leads sys.path, so the absolute name resolves there.
    selected = [(key, importlib.import_module(f".{name}", __package__)
                 if __package__ else importlib.import_module(name))
                for key, name in selected_names]
    if args.json:
        os.makedirs(args.json, exist_ok=True)
    baselines, invalid_baselines = \
        _load_baselines(selected, args.baseline) if args.check else ({}, [])
    if invalid_baselines:
        # fail fast: running the suites first would waste minutes before
        # telling the user their reference files need regenerating
        print("error: invalid perf-gate baselines:\n  "
              + "\n  ".join(invalid_baselines))
        raise SystemExit(EXIT_MISCONFIGURED)
    print("name,us_per_call,derived")
    failures = 0
    all_regressions = []
    all_misconfigured = []
    missing_baselines = []
    for key, mod in selected:
        if args.trace:
            from repro import obs
            obs.clear()  # per-suite trace files, not one giant ring
        t0 = time.time()
        rows, error = [], None
        try:
            for row in mod.run():
                rows.append(row)
                print(row.csv(), flush=True)
        except Exception as exc:
            failures += 1
            error = f"{type(exc).__name__}: {exc}"
            print(f"{key},nan,ERROR", flush=True)
            traceback.print_exc()
        wall = time.time() - t0
        print(f"# {key} done in {wall:.1f}s", flush=True)
        if args.trace:
            from repro import obs
            from repro.faults import atomic_write_json
            from repro.obs.trace import write_trace
            n = write_trace(os.path.join(args.trace, f"trace_{key}.json"))
            atomic_write_json(os.path.join(args.trace,
                                           f"metrics_{key}.json"),
                              _jsonable(obs.metrics_snapshot()))
            print(f"# trace {key}: {n} events -> "
                  f"{args.trace}/trace_{key}.json", flush=True)
        if args.json:
            from repro.faults import atomic_write_json
            payload = {
                "suite": key,
                "wall_time_s": round(wall, 3),
                "error": error,
                "rows": [{"name": r.name,
                          "us_per_call": _jsonable(round(float(r.us), 1)),
                          "derived": _jsonable(r.derived)} for r in rows],
            }
            path = os.path.join(args.json, f"BENCH_{key}.json")
            atomic_write_json(path, payload)  # stamps the checksum field
            print(f"# wrote {path}", flush=True)
        if args.check and error is None:
            base = baselines.get(key)
            if base is None:
                missing_baselines.append(key)
            else:
                regs, miscfg = _check_suite(key, rows, wall, base,
                                            args.tolerance)
                all_regressions += regs
                all_misconfigured += miscfg
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")
    if args.check:
        if missing_baselines:
            print("error: no committed baseline for: "
                  + ", ".join(f"BENCH_{k}.json" for k in missing_baselines)
                  + " (generate with --json and commit before gating)")
            raise SystemExit(EXIT_MISCONFIGURED)
        if all_misconfigured:
            print("error: misconfigured perf gate:\n  "
                  + "\n  ".join(all_misconfigured))
            raise SystemExit(EXIT_MISCONFIGURED)
        if all_regressions:
            raise SystemExit("perf regressions vs committed baselines:\n  "
                             + "\n  ".join(all_regressions))
        print("# --check passed: no perf regressions", flush=True)


if __name__ == "__main__":
    main()
