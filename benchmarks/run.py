# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure (+ roofline).

Usage:
    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig6 tab5  # substring filter
"""

import sys
import time
import traceback


def main() -> None:
    from . import (bench_entry_size, bench_flexible_robustness,
                   bench_nominal_designs, bench_rho_choice, bench_rho_impact,
                   bench_robust_sharding, bench_robust_vs_nominal,
                   bench_roofline, bench_system_eval, bench_tuner_perf)
    suites = [
        ("fig4", bench_nominal_designs),
        ("fig6", bench_robust_vs_nominal),
        ("fig7_8", bench_rho_impact),
        ("fig9", bench_rho_choice),
        ("fig10", bench_entry_size),
        ("tab5", bench_system_eval),
        ("fig19", bench_flexible_robustness),
        ("tuner", bench_tuner_perf),
        ("roofline", bench_roofline),
        ("robust_sharding", bench_robust_sharding),
    ]
    filters = [a for a in sys.argv[1:] if not a.startswith("-")]
    print("name,us_per_call,derived")
    failures = 0
    for key, mod in suites:
        if filters and not any(f in key for f in filters):
            continue
        t0 = time.time()
        try:
            for row in mod.run():
                print(row.csv(), flush=True)
        except Exception:
            failures += 1
            print(f"{key},nan,ERROR", flush=True)
            traceback.print_exc()
        print(f"# {key} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
