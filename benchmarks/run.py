# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure (+ roofline).

Usage:
    PYTHONPATH=src python -m benchmarks.run                  # all
    PYTHONPATH=src python -m benchmarks.run fig6 tab5        # substring filter
    PYTHONPATH=src python -m benchmarks.run --json out/      # + BENCH_*.json

``--json OUT`` writes one ``BENCH_<suite>.json`` per executed suite into the
OUT directory: per-suite wall time plus every row's derived metrics, so later
PRs have a machine-readable perf trajectory to compare against.
"""

import argparse
import json
import math
import os
import time
import traceback


def _jsonable(x):
    """Best-effort conversion of derived metric values to *strict* JSON types
    (non-finite floats become null: consumers parse these files with strict
    parsers, which reject the bare NaN/Infinity literals json.dump emits)."""
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, bool) or x is None:
        return x
    if hasattr(x, "item"):          # numpy / jax scalars
        try:
            return _jsonable(x.item())
        except Exception:
            return str(x)
    if isinstance(x, float):
        return x if math.isfinite(x) else None
    if isinstance(x, (int, str)):
        return x
    return str(x)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("filters", nargs="*",
                        help="substring filters on suite names")
    parser.add_argument("--json", metavar="OUT", default=None,
                        help="directory to write per-suite BENCH_<suite>.json")
    args = parser.parse_args()

    from . import (bench_entry_size, bench_flexible_robustness,
                   bench_nominal_designs, bench_rho_choice, bench_rho_impact,
                   bench_robust_sharding, bench_robust_vs_nominal,
                   bench_roofline, bench_system_eval, bench_tuner_perf)
    suites = [
        ("fig4", bench_nominal_designs),
        ("fig6", bench_robust_vs_nominal),
        ("fig7_8", bench_rho_impact),
        ("fig9", bench_rho_choice),
        ("fig10", bench_entry_size),
        ("tab5", bench_system_eval),
        ("fig19", bench_flexible_robustness),
        ("tuner", bench_tuner_perf),
        ("roofline", bench_roofline),
        ("robust_sharding", bench_robust_sharding),
    ]
    if args.json:
        os.makedirs(args.json, exist_ok=True)
    print("name,us_per_call,derived")
    failures = 0
    for key, mod in suites:
        if args.filters and not any(f in key for f in args.filters):
            continue
        t0 = time.time()
        rows, error = [], None
        try:
            for row in mod.run():
                rows.append(row)
                print(row.csv(), flush=True)
        except Exception as exc:
            failures += 1
            error = f"{type(exc).__name__}: {exc}"
            print(f"{key},nan,ERROR", flush=True)
            traceback.print_exc()
        wall = time.time() - t0
        print(f"# {key} done in {wall:.1f}s", flush=True)
        if args.json:
            payload = {
                "suite": key,
                "wall_time_s": round(wall, 3),
                "error": error,
                "rows": [{"name": r.name,
                          "us_per_call": _jsonable(round(float(r.us), 1)),
                          "derived": _jsonable(r.derived)} for r in rows],
            }
            path = os.path.join(args.json, f"BENCH_{key}.json")
            with open(path, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True,
                          allow_nan=False)
            print(f"# wrote {path}", flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
