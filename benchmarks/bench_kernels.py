"""Kernel-tier microbenchmarks: reference vs fused vs Pallas paths.

One row per kernel family (PR 7's fused data plane):

* ``kernels_point_read`` — the per-level fused batched point read
  (Bloom probe + fence location + per-run binary search).  The gated
  comparison is the production fused numpy path against the eager jnp
  reference (``kernels.point_read.ref``) on the same level arenas; the
  Pallas leg runs in interpret mode off-TPU and is reported unguarded
  (interpret timings measure the Python evaluator, not the kernel).
* ``kernels_dual_solve`` — the robust tuner's warm dual solve.  Gated:
  the cached-point fused solve (12 g-evaluations) vs the two-point
  reference (16 g-evaluations), both jit-compiled over the same lane
  batch via ``dual_solve_warm_batch``.
* ``kernels_merge`` — the compaction k-way stable merge.  Reported
  (numpy argsort vs jnp rank-merge vs Pallas merge-path), not gated:
  on CPU the argsort baseline is already memory-bound and the jnp path
  pays eager-dispatch overhead by design.

Every row also carries *effective* achieved bytes/s, derived from the
engine's own I/O accounting (filter words probed + pages read for the
point read; inputs + outputs for the merge; cost matrices for the
solve).  ``bench_roofline`` reuses :func:`measure_cells` to place these
against a measured host-copy bandwidth ceiling.
"""

from __future__ import annotations

import math
import time
from typing import Callable, Dict, List

import numpy as np

from .common import Row

# Modest sizes: the Pallas legs run under the interpret-mode Python
# evaluator off-TPU, so every grid step is host work.
PR_BATCH = 512          # point-read query batch
DS_LANES = 1024         # dual-solve lane count
DS_COSTS = 64           # workloads per lane cost vector
DS_STEPS = 12           # chained warm solves per call (the tuner's Adam
                        # loop re-solves every step with the warm llam)
MG_SIZES = (20_000, 15_000, 5_000)   # newest-first run lengths


def _best_us(fn: Callable[[], object], repeats: int = 5,
             warmup: int = 1) -> float:
    """Best-of-N wall time in microseconds (min: least-noise estimator)."""
    for _ in range(warmup):
        fn()
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _point_read_fixture():
    """A populated tree level + query batch (half present, half absent)."""
    from repro.lsm import EngineConfig, LSMTree
    tree = LSMTree(EngineConfig(T=4, K=(3, 3, 3), buf_entries=256,
                                expected_entries=30_000,
                                mfilt_bits_per_entry=8.0))
    rng = np.random.default_rng(7)
    keys = rng.choice(1 << 40, 30_000, replace=False).astype(np.uint64)
    tree.put_batch(keys, [int(k) % 997 for k in keys])
    tree.flush()
    # Prefer a multi-run level (exercises newest->oldest masking), then
    # the biggest one.
    lv = max((lv for lv in tree.store.levels if lv.num_runs),
             key=lambda lv: (lv.num_runs, len(lv.keys)))
    q = np.concatenate([
        rng.choice(keys, PR_BATCH // 2, replace=False),
        rng.choice(1 << 40, PR_BATCH - PR_BATCH // 2).astype(np.uint64),
    ]).astype(np.uint64)
    return tree, lv, q


def _point_read_cell() -> Dict[str, float]:
    from repro.kernels.point_read.ops import point_read_level_arrays
    from repro.lsm.read_path import point_read_level_numpy

    tree, lv, q = _point_read_fixture()
    pack = lv.pack
    starts = np.asarray(lv.starts, np.int64)
    n_bits = np.asarray(pack.n_bits, np.uint64)
    ks = np.asarray(pack.ks, np.int64)

    def via_arrays(impl):
        return point_read_level_arrays(q, lv.keys, lv.vals, starts,
                                       pack.words, n_bits, ks, lv.min_keys,
                                       lv.max_keys, impl=impl)

    us_numpy = _best_us(lambda: point_read_level_numpy(lv, q))
    us_jnp = _best_us(lambda: via_arrays("jnp"), repeats=3)
    us_pallas = _best_us(lambda: via_arrays("pallas"), repeats=1)

    # Effective bytes from the engine's own I/O model: every probe
    # touches k 8-byte filter words, every bloom-positive read costs one
    # page, plus the query batch itself.
    _, _, probes, reads, fps = point_read_level_numpy(lv, q)
    k_mean = float(np.mean(ks)) if len(ks) else 0.0
    eff_bytes = 8 * len(q) + probes * k_mean * 8 \
        + reads * tree.cfg.page_bytes
    return {"us_numpy": us_numpy, "us_jnp_ref": us_jnp,
            "us_pallas_interpret": us_pallas,
            "probes": probes, "reads": reads, "false_positives": fps,
            "runs": lv.num_runs, "level_entries": len(lv.keys),
            "batch": len(q), "effective_bytes": eff_bytes,
            "achieved_gbps": eff_bytes / (us_numpy * 1e-6) / 1e9,
            "speedup_fused_vs_ref": us_jnp / us_numpy}


def _dual_solve_cell() -> Dict[str, float]:
    import functools

    import jax
    from repro.kernels.dual_solve.ops import dual_solve_warm_batch

    rng = np.random.default_rng(3)
    C = rng.gamma(2.0, 2.0, (DS_LANES, DS_COSTS)).astype(np.float32)
    W = rng.dirichlet(np.ones(DS_COSTS), DS_LANES).astype(np.float32)
    rho = np.full(DS_LANES, 0.25, np.float32)
    llam = np.log(C.max(1) - C.min(1)).astype(np.float32)

    # The production shape: every Adam step re-solves warm-started from
    # the previous llam, so one "call" here is a DS_STEPS-long chain —
    # that amortizes dispatch and measures the 12-vs-16-eval core.
    @functools.partial(jax.jit, static_argnames=("impl",))
    def chain(C, W, rho, llam, impl):
        def body(ll, _):
            v, ll2 = dual_solve_warm_batch(C, W, rho, ll, impl=impl)
            return ll2, v
        ll, vs = jax.lax.scan(body, llam, None, length=DS_STEPS)
        return ll, vs

    def run(impl, repeats=5):
        def call():
            jax.block_until_ready(chain(C, W, rho, llam, impl=impl))
        return _best_us(call, repeats=repeats)

    us_ref = run("ref")
    us_fused = run("fused")
    us_pallas = run("pallas", repeats=1)
    eff_bytes = DS_STEPS * (C.nbytes + W.nbytes + rho.nbytes + llam.nbytes
                            + 2 * DS_LANES * 4)
    return {"us_ref": us_ref, "us_fused": us_fused,
            "us_pallas_interpret": us_pallas,
            "lanes": DS_LANES, "costs_per_lane": DS_COSTS,
            "chain_steps": DS_STEPS,
            "g_evals_ref": 16, "g_evals_fused": 12,
            "effective_bytes": eff_bytes,
            "achieved_gbps": eff_bytes / (us_fused * 1e-6) / 1e9,
            "speedup_fused_vs_ref": us_ref / us_fused}


def _merge_cell() -> Dict[str, float]:
    from repro.kernels.merge.ops import merge_runs_arrays
    from repro.lsm.merge_path import merge_runs_numpy

    rng = np.random.default_rng(11)
    keys_list, vals_list = [], []
    for i, n in enumerate(MG_SIZES):
        k = np.sort(rng.choice(1 << 40, n, replace=False).astype(np.uint64))
        keys_list.append(k)
        vals_list.append(rng.integers(0, 1 << 30, n).astype(np.int64))

    us_numpy = _best_us(lambda: merge_runs_numpy(keys_list, vals_list))
    us_jnp = _best_us(lambda: merge_runs_arrays(keys_list, vals_list,
                                                impl="jnp"), repeats=3)
    us_pallas = _best_us(lambda: merge_runs_arrays(keys_list, vals_list,
                                                   impl="pallas"),
                         repeats=1)
    n_total = sum(MG_SIZES)
    eff_bytes = 2 * n_total * 16        # read keys+vals, write keys+vals
    return {"us_numpy": us_numpy, "us_jnp": us_jnp,
            "us_pallas_interpret": us_pallas,
            "entries": n_total, "runs": len(MG_SIZES),
            "effective_bytes": eff_bytes,
            "achieved_gbps": eff_bytes / (us_numpy * 1e-6) / 1e9}


#: cell name -> measurement fn; bench_roofline reuses this registry.
CELLS = {
    "point_read": _point_read_cell,
    "dual_solve": _dual_solve_cell,
    "merge": _merge_cell,
}


def measure_cells() -> Dict[str, Dict[str, float]]:
    """Run every kernel cell once; used here and by bench_roofline."""
    return {name: fn() for name, fn in CELLS.items()}


def run() -> List[Row]:
    rows: List[Row] = []
    for name, fn in CELLS.items():
        d = fn()
        us = d.get("us_numpy", d.get("us_fused", 0.0))
        rows.append(Row(f"kernels_{name}", us, **d))
    return rows
