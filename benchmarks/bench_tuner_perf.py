"""Section Perf (tuner): the JAX vmapped multi-start tuner vs SciPy SLSQP.

The paper (Section 11, Limitations) reports SLSQP instability for the most
flexible designs.  Here we measure (a) solution quality parity on CLASSIC,
(b) quality + stability on K-LSM (26 decision vars), and (c) tunings/sec
throughput of the vmapped tuner (the whole 15-workload sweep is one jit).
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import (EXPECTED_WORKLOADS, DesignSpace, tune_nominal,
                        tune_nominal_slsqp)
from .common import SYS, Row


def run() -> List[Row]:
    rows: List[Row] = []
    w7 = EXPECTED_WORKLOADS[7]

    # quality parity on the classic design
    t0 = time.time()
    r_jax = tune_nominal(w7, SYS, seed=0)
    t_jax = time.time() - t0
    t0 = time.time()
    r_slsqp = tune_nominal_slsqp(w7, SYS, seed=0)
    t_slsqp = time.time() - t0
    rows.append(Row("perf_tuner_classic", t_jax * 1e6,
                    jax_cost=round(r_jax.cost, 4),
                    slsqp_cost=round(r_slsqp.cost, 4),
                    quality_ratio=round(r_slsqp.cost / r_jax.cost, 3),
                    slsqp_us=round(t_slsqp * 1e6, 1)))

    # K-LSM stability: solve from several seeds, measure spread
    jax_costs, slsqp_costs = [], []
    t0 = time.time()
    for seed in range(4):
        jax_costs.append(tune_nominal(w7, SYS, DesignSpace.KLSM,
                                      n_starts=128, seed=seed).cost)
    t_jax = (time.time() - t0) / 4
    t0 = time.time()
    for seed in range(4):
        slsqp_costs.append(tune_nominal_slsqp(w7, SYS, DesignSpace.KLSM,
                                              n_starts=6, seed=seed).cost)
    t_slsqp = (time.time() - t0) / 4
    spread = lambda v: (max(v) - min(v)) / min(v)
    rows.append(Row(
        "perf_tuner_klsm_stability", t_jax * 1e6,
        jax_best=round(min(jax_costs), 4),
        jax_spread=round(spread(jax_costs), 4),
        slsqp_best=round(min(slsqp_costs), 4),
        slsqp_spread=round(spread(slsqp_costs), 4),
        claim_jax_more_stable=spread(jax_costs) <= spread(slsqp_costs),
        claim_jax_no_worse=min(jax_costs) <= min(slsqp_costs) * 1.02,
        slsqp_us=round(t_slsqp * 1e6, 1)))

    # throughput: steady-state tunings/sec after warmup (jit cached)
    tune_nominal(EXPECTED_WORKLOADS[1], SYS, seed=0)  # warm
    t0 = time.time()
    n = 0
    for w in EXPECTED_WORKLOADS:
        tune_nominal(w, SYS, seed=1)
        n += 1
    dt = time.time() - t0
    rows.append(Row("perf_tuner_throughput", dt / n * 1e6,
                    tunings_per_sec=round(n / dt, 2),
                    paper_reports="<1s per tuning (Sec 6.2); <10ms Sec 9.3"))
    return rows
