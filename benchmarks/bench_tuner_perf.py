"""Section Perf (tuner): the JAX vmapped multi-start tuner vs SciPy SLSQP,
plus the batched sweep engine vs per-cell dispatch.

The paper (Section 11, Limitations) reports SLSQP instability for the most
flexible designs.  Here we measure (a) solution quality parity on CLASSIC,
(b) quality + stability on K-LSM (26 decision vars), (c) tunings/sec of the
batched nominal tuner (the whole 15-workload sweep is one jit), and (d) the
headline sweep row: the full Fig. 6 grid (15 workloads x 5 rhos, CLASSIC)
solved three ways —

  * ``seed-style``: one jit call per (cell, design) with the dual re-solved
    from a cold 64-point grid + 40 golden iterations at *every* Adam step and
    CLASSIC as two recursive solves (faithful to the pre-batching tuner,
    including its two objective evaluations per step);
  * ``sequential``: today's `tune_robust` (warm-started dual, folded CLASSIC)
    called once per cell;
  * ``batched``: one `tune_robust_many` dispatch for the whole grid.

The acceptance bar is batched >= 10x over the per-cell loop with per-cell
costs matching the sequential path within 1%.
"""

from __future__ import annotations

import time
from functools import partial
from typing import List

import numpy as np

from repro.core import (EXPECTED_WORKLOADS, DesignSpace, tune_nominal,
                        tune_nominal_many, tune_nominal_slsqp, tune_robust,
                        tune_robust_many)
from .common import SYS, Row

# Fig. 6 grid for the sweep-throughput row; solver params shared by all
# three implementations so wall-clock differences are pure dispatch/algorithm.
GRID_RHOS = (0.25, 0.5, 1.0, 2.0, 3.0)
GRID_STARTS = 32
GRID_STEPS = 150


# ---------------------------------------------------------------------------
# Seed-style per-cell robust tuner (the pre-batching baseline), kept here so
# the benchmark keeps measuring the dispatch pattern this PR replaced.
# ---------------------------------------------------------------------------

def _seed_minimize_adam(obj, theta0, steps, lr, lr_decay=0.1):
    """The seed's fori_loop Adam: grad at theta, step, then re-evaluate the
    objective at theta_new (two objective evaluations per step)."""
    import jax
    import jax.numpy as jnp

    from repro.core._opt import adam_init, adam_update

    g = jax.grad(lambda t: obj(t))

    def body(i, carry):
        theta, st, best_t, best_v = carry
        frac = i / max(steps - 1, 1)
        lr_i = lr * (lr_decay + (1 - lr_decay) * 0.5 *
                     (1 + jnp.cos(jnp.pi * frac)))
        grad = g(theta)
        grad = jnp.where(jnp.isfinite(grad), grad, 0.0)
        delta, st = adam_update(grad, st, lr_i)
        theta = theta - delta
        v = obj(theta)
        better = jnp.isfinite(v) & (v < best_v)
        best_t = jnp.where(better, theta, best_t)
        best_v = jnp.where(better, v, best_v)
        return theta, st, best_t, best_v

    v0 = obj(theta0)
    v0 = jnp.where(jnp.isfinite(v0), v0, jnp.inf)
    init = (theta0, adam_init(theta0), theta0, v0)
    _, _, best_t, best_v = jax.lax.fori_loop(0, steps, body, init)
    return best_t, best_v


def _seed_style_cell_factory():
    import jax
    import jax.numpy as jnp

    from repro.core import designs
    from repro.core.lsm_cost import (empty_read_cost, nonempty_read_cost,
                                     range_cost, write_cost)
    from repro.core.robust import robust_cost

    def seed_cost_vector(phi, sys, smooth):
        # The seed's unfused cost vector: one stack of the four components,
        # each recomputing L / FPRs / masks (this PR fused them).
        return jnp.stack([
            empty_read_cost(phi, sys, smooth=smooth),
            nonempty_read_cost(phi, sys, smooth=smooth),
            range_cost(phi, sys, smooth=smooth),
            write_cost(phi, sys, smooth=smooth)])

    @partial(jax.jit,
             static_argnames=("design", "sys", "n_starts", "steps", "lr"))
    def cell(key, w, rho, design, sys, n_starts, steps, lr):
        thetas = designs.random_inits(key, n_starts, design, sys)

        def obj(theta):
            phi = designs.to_phi(theta, design, sys, smooth=True)
            return robust_cost(seed_cost_vector(phi, sys, smooth=True),
                               w, rho)

        best_t, _ = jax.vmap(
            lambda t0: _seed_minimize_adam(obj, t0, steps=steps,
                                           lr=lr))(thetas)

        def exact(theta):
            phi = designs.to_phi(theta, design, sys,
                                 smooth=False).round_integral(sys)
            return robust_cost(seed_cost_vector(phi, sys, smooth=False),
                               w, rho)

        ex = jax.vmap(exact)(best_t)
        i = jnp.argmin(jnp.where(jnp.isfinite(ex), ex, jnp.inf))
        return best_t[i], ex[i]

    def tune(w, rho, seed=1, lr=0.25):
        key = jax.random.PRNGKey(seed)
        best = np.inf
        for d in (DesignSpace.LEVELING, DesignSpace.TIERING):
            _, c = cell(key, jnp.asarray(w, jnp.float32),
                        jnp.asarray(rho, jnp.float32), d, SYS,
                        GRID_STARTS, GRID_STEPS, lr)
            best = min(best, float(c))
        return best

    return tune


def run() -> List[Row]:
    rows: List[Row] = []
    w7 = EXPECTED_WORKLOADS[7]

    # quality parity on the classic design
    t0 = time.time()
    r_jax = tune_nominal(w7, SYS, seed=0)
    t_jax = time.time() - t0
    t0 = time.time()
    r_slsqp = tune_nominal_slsqp(w7, SYS, seed=0)
    t_slsqp = time.time() - t0
    rows.append(Row("perf_tuner_classic", t_jax * 1e6,
                    jax_cost=round(r_jax.cost, 4),
                    slsqp_cost=round(r_slsqp.cost, 4),
                    quality_ratio=round(r_slsqp.cost / r_jax.cost, 3),
                    slsqp_us=round(t_slsqp * 1e6, 1)))

    # K-LSM stability: solve from several seeds, measure spread
    jax_costs, slsqp_costs = [], []
    t0 = time.time()
    for seed in range(4):
        jax_costs.append(tune_nominal(w7, SYS, DesignSpace.KLSM,
                                      n_starts=128, seed=seed).cost)
    t_jax = (time.time() - t0) / 4
    t0 = time.time()
    for seed in range(4):
        slsqp_costs.append(tune_nominal_slsqp(w7, SYS, DesignSpace.KLSM,
                                              n_starts=6, seed=seed).cost)
    t_slsqp = (time.time() - t0) / 4
    spread = lambda v: (max(v) - min(v)) / min(v)
    rows.append(Row(
        "perf_tuner_klsm_stability", t_jax * 1e6,
        jax_best=round(min(jax_costs), 4),
        jax_spread=round(spread(jax_costs), 4),
        slsqp_best=round(min(slsqp_costs), 4),
        slsqp_spread=round(spread(slsqp_costs), 4),
        claim_jax_more_stable=spread(jax_costs) <= spread(slsqp_costs),
        claim_jax_no_worse=min(jax_costs) <= min(slsqp_costs) * 1.02,
        slsqp_us=round(t_slsqp * 1e6, 1)))

    # nominal throughput: the 15-workload sweep as one dispatch (jit warm)
    tune_nominal_many(EXPECTED_WORKLOADS, SYS, seed=1)  # warm
    t0 = time.time()
    n = len(tune_nominal_many(EXPECTED_WORKLOADS, SYS, seed=1))
    dt = time.time() - t0
    rows.append(Row("perf_tuner_throughput", dt / n * 1e6,
                    tunings_per_sec=round(n / dt, 2),
                    batch="15 workloads, one jit",
                    paper_reports="<1s per tuning (Sec 6.2); <10ms Sec 9.3"))

    # headline: the Fig. 6 robust grid, per-cell vs batched (jit warm for all)
    seed_style = _seed_style_cell_factory()
    kw = dict(n_starts=GRID_STARTS, steps=GRID_STEPS, seed=1)
    seed_style(EXPECTED_WORKLOADS[0], 1.0)                       # warm
    tune_robust(EXPECTED_WORKLOADS[0], 1.0, SYS, **kw)           # warm
    tune_robust_many(EXPECTED_WORKLOADS, GRID_RHOS, SYS, **kw)   # warm

    t0 = time.time()
    batched = tune_robust_many(EXPECTED_WORKLOADS, GRID_RHOS, SYS, **kw)
    t_batched = time.time() - t0

    t0 = time.time()
    sequential = [[tune_robust(w, rho, SYS, **kw) for rho in GRID_RHOS]
                  for w in EXPECTED_WORKLOADS]
    t_seq = time.time() - t0

    t0 = time.time()
    seed_costs = [[seed_style(w, rho, seed=1) for rho in GRID_RHOS]
                  for w in EXPECTED_WORKLOADS]
    t_seed = time.time() - t0

    seq_diff = max(abs(b.cost - s.cost) / max(s.cost, 1e-12)
                   for brow, srow in zip(batched, sequential)
                   for b, s in zip(brow, srow))
    seed_diff = max(abs(b.cost - c) / max(c, 1e-12)
                    for brow, crow in zip(batched, seed_costs)
                    for b, c in zip(brow, crow))
    n_cells = len(EXPECTED_WORKLOADS) * len(GRID_RHOS)
    rows.append(Row(
        "perf_tuner_fig6_grid", t_batched / n_cells * 1e6,
        cells=n_cells,
        batched_s=round(t_batched, 2),
        sequential_s=round(t_seq, 2),
        seed_style_s=round(t_seed, 2),
        speedup_vs_sequential=round(t_seq / t_batched, 1),
        speedup_vs_seed_style=round(t_seed / t_batched, 1),
        claim_speedup_ge_10x=bool(t_seed / t_batched >= 10.0),
        max_rel_cost_diff_vs_sequential=round(seq_diff, 6),
        claim_costs_match_1pct=bool(seq_diff < 0.01),
        max_rel_cost_diff_vs_seed_style=round(seed_diff, 4)))
    return rows
