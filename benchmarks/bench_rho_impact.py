"""Paper Figures 7 & 8: impact of rho for w11 (read-heavy).

Fig 7: Delta(Phi_N, Phi_R) grows with the observed KL-divergence; rho=0
matches nominal.  Fig 8: the throughput range Theta_B shrinks as rho grows
(robustness = consistency).

One declarative spec: w11 x four rhos + the nominal baseline, model-scored
over the benchmark set."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.api import ExperimentSpec, Row, WorkloadSpec, run_experiment
from repro.core import EXPECTED_WORKLOADS, kl_divergence, throughput_range

RHOS = (0.0, 0.5, 1.0, 2.0)

SPEC = ExperimentSpec(
    name="fig7_8",
    workload=WorkloadSpec(indices=(11,), rhos=RHOS, nominal=True,
                          bench_n=10_000, bench_seed=0),
)


def run() -> List[Row]:
    import jax.numpy as jnp
    t0 = time.time()
    report = run_experiment(SPEC)
    B = report.bench_set
    w11 = EXPECTED_WORKLOADS[11]
    kls = np.asarray([float(kl_divergence(jnp.asarray(w),
                                          jnp.asarray(w11)))
                      for w in B])
    bins = [(0.0, 0.2), (0.2, 0.5), (0.5, 1.0), (1.0, 2.0), (2.0, 10.0)]

    rows: List[Row] = []
    theta_by_rho = {}
    for rho in RHOS:
        d = report.delta_tp_vs_nominal(0, rho)
        derived = {}
        for lo, hi in bins:
            sel = (kls >= lo) & (kls < hi)
            if sel.any():
                derived[f"delta_kl_{lo}_{hi}"] = round(float(d[sel].mean()),
                                                       3)
        theta = float(throughput_range(jnp.asarray(B, jnp.float32),
                                       report.tuning((0, rho)).phi,
                                       report.sys))
        theta_by_rho[rho] = theta
        derived["theta_range"] = round(theta, 4)
        rows.append(Row(f"fig7_delta_vs_kl_rho{rho}", 0.0, **derived))
    us = (time.time() - t0) * 1e6 / len(RHOS)
    for r in rows:
        r.us = us

    # Fig 8 claim: Theta decreases with rho (higher consistency).
    thetas = [theta_by_rho[r] for r in RHOS]
    rows.append(Row("fig8_theta_shrinks", us,
                    theta_rho0=round(thetas[0], 4),
                    theta_rho2=round(thetas[-1], 4),
                    claim_monotone_shrink=bool(thetas[-1] < thetas[0])))
    # Fig 7 claim: rho=0 ~= nominal; gain grows with KL at rho>=1.
    return rows
