"""Paper Figures 7 & 8: impact of rho for w11 (read-heavy).

Fig 7: Delta(Phi_N, Phi_R) grows with the observed KL-divergence; rho=0
matches nominal.  Fig 8: the throughput range Theta_B shrinks as rho grows
(robustness = consistency).

All four robust tunings come from one `tune_robust_many` dispatch."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import (EXPECTED_WORKLOADS, kl_divergence, throughput_range,
                        tune_nominal, tune_robust_many)
from .common import B_SET, SYS, Row, costs_over_B, delta_tp

W11 = EXPECTED_WORKLOADS[11]
RHOS = (0.0, 0.5, 1.0, 2.0)


def run() -> List[Row]:
    import jax.numpy as jnp
    t0 = time.time()
    rn = tune_nominal(W11, SYS, seed=0)
    cn = costs_over_B(rn.phi)
    robust = tune_robust_many([W11], RHOS, SYS, seed=0)[0]
    kls = np.asarray([float(kl_divergence(jnp.asarray(w),
                                          jnp.asarray(W11)))
                      for w in B_SET])
    bins = [(0.0, 0.2), (0.2, 0.5), (0.5, 1.0), (1.0, 2.0), (2.0, 10.0)]

    rows: List[Row] = []
    theta_by_rho = {}
    for j, rho in enumerate(RHOS):
        rr = robust[j]
        cr = costs_over_B(rr.phi)
        d = delta_tp(cn, cr)
        derived = {}
        for lo, hi in bins:
            sel = (kls >= lo) & (kls < hi)
            if sel.any():
                derived[f"delta_kl_{lo}_{hi}"] = round(float(d[sel].mean()),
                                                       3)
        theta = float(throughput_range(jnp.asarray(B_SET, jnp.float32),
                                       rr.phi, SYS))
        theta_by_rho[rho] = theta
        derived["theta_range"] = round(theta, 4)
        rows.append(Row(f"fig7_delta_vs_kl_rho{rho}", 0.0, **derived))
    us = (time.time() - t0) * 1e6 / len(RHOS)
    for r in rows:
        r.us = us

    # Fig 8 claim: Theta decreases with rho (higher consistency).
    thetas = [theta_by_rho[r] for r in RHOS]
    rows.append(Row("fig8_theta_shrinks", us,
                    theta_rho0=round(thetas[0], 4),
                    theta_rho2=round(thetas[-1], 4),
                    claim_monotone_shrink=bool(thetas[-1] < thetas[0])))
    # Fig 7 claim: rho=0 ~= nominal; gain grows with KL at rho>=1.
    return rows
