"""API smoke suite: one tiny declarative experiment, end to end.

Exercises the whole facade in CI-gate-sized form — spec -> JSON -> spec
round-trip, a two-workload (nominal + robust) grid with the compaction
policy as a discrete arm, and a reduced-scale engine trial — and emits the
unified report's rows.  The perf gate watches ``api_fleet.engine_s``, so a
regression in the facade's lowering (extra dispatches, lost plan sharing)
shows up here without running the full Table-5 suite.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.api import (DesignSpec, ExperimentSpec, Row, TrialSpec,
                       WorkloadSpec, run_experiment)

N_KEYS = 40_000
QUERIES = 2000
SESSIONS = (
    (0.05, 0.85, 0.05, 0.05),
    (0.05, 0.05, 0.05, 0.85),
)

SPEC = ExperimentSpec(
    name="api",
    workload=WorkloadSpec(indices=(4, 11), rhos=(1.0,), nominal=True),
    design=DesignSpec(n_starts=16, steps=120, seed=0,
                      policies=("klsm", "lazy_leveling"),
                      policy_params=(
                          ("lazy_leveling", (("read_trigger", 512),)),)),
    trial=TrialSpec(n_keys=N_KEYS, n_queries=QUERIES, sessions=SESSIONS,
                    key_space=2 ** 24, range_fraction=1e-3,
                    per_workload_keys=True, key_seed=100),
    system=(("N", float(N_KEYS)), ("entry_bits", 64.0 * 8),
            ("page_bits", 4096.0 * 8), ("bits_per_entry", 6.0),
            ("min_buf_bits", 64.0 * 8 * 64), ("s_rq", 1e-3),
            ("max_T", 20.0)),
)


def run() -> List[Row]:
    # the JSON round-trip is part of the smoke surface
    spec = ExperimentSpec.from_json(SPEC.to_json())
    assert spec == SPEC, "ExperimentSpec JSON round-trip drifted"
    report = run_experiment(spec)

    rows = report.rows()           # one row per cell + the walls row
    walls = report.walls
    measured = np.concatenate([report.measured_io(c) for c in report.cells])
    model = np.concatenate([
        np.asarray(report.model_session_io(c, SESSIONS)).ravel()
        for c in report.cells])
    rows.append(Row(
        "api_fleet", report.wall_time_s * 1e6,
        n_keys=N_KEYS, n_queries=QUERIES, trees=len(report.fleet),
        sessions_per_tree=len(SESSIONS),
        tuning_s=round(walls["tuning_s"], 2),
        engine_s=round(walls["populate_s"] + walls["fleet_s"], 2),
        mean_agreement=round(float(measured.mean() / model.mean()), 3),
        arms_chosen={f"w{i}" + ("" if rho is None else f"_rho{rho:g}"):
                     report.chosen[(i, rho)]
                     for (i, rho) in report.cells},
    ))
    return rows
