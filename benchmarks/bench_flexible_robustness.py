"""Paper Figure 19 + Section 10: flexibility does NOT imply robustness.

For w7 and w11, obtain nominal tunings from every design (incl. K-LSM,
Fluid, Lazy Leveling, Dostoevsky) and ENDURE's robust tuning (rho=2), then
evaluate C(w_hat, Phi) as the observed workload drifts away (binned by
KL-divergence).

Claims: flexible designs win at KL ~ 0 (Fig 4 regime) but degrade like the
classic nominal tunings under drift; only the robust tuning stays flat —
robustness comes from the tuning process, not the design.

The nominal designs are ONE declarative spec with the design space as a
real axis (``DesignSpec.spaces``, each arm tuned over the shared cell grid
and scored on the shared benchmark set) instead of the old one-spec-per-
design loop; the robust reference stays its own spec (a different tuning
process, not another design arm).  Derived metrics are byte-identical to
the per-design loop: the same (space, n_starts, seed) grids solve, only the
orchestration collapsed."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.api import (DesignSpec, ExperimentSpec, Row, WorkloadSpec,
                       run_experiment)
from repro.core import EXPECTED_WORKLOADS, kl_divergence

WIDX = (7, 11)
#: curve label -> design-space arm (name, n_starts) of the axis spec
NOMINAL_MODELS = [
    ("nominal_classic", "classic", 64),
    ("lazy_leveling", "lazy_leveling", 64),
    ("dostoevsky", "dostoevsky", 64),
    ("fluid", "fluid", 64),
    ("klsm", "klsm", 192),
]
BINS = [(0.0, 0.2), (0.5, 1.0), (2.0, 6.0)]


def _spec(name: str, space: str, n_starts: int, rhos=()) -> ExperimentSpec:
    return ExperimentSpec(
        name=f"fig19_{name}",
        workload=WorkloadSpec(indices=WIDX, rhos=rhos, nominal=not rhos,
                              bench_n=10_000, bench_seed=0),
        design=DesignSpec(space=space, n_starts=n_starts, seed=0))


def run() -> List[Row]:
    import jax.numpy as jnp
    t0 = time.time()
    axis = run_experiment(ExperimentSpec(
        name="fig19_designs",
        workload=WorkloadSpec(indices=WIDX, nominal=True,
                              bench_n=10_000, bench_seed=0),
        design=DesignSpec(space="classic", n_starts=64, seed=0,
                          spaces=tuple((space, n_starts) for _, space,
                                       n_starts in NOMINAL_MODELS))))
    robust = run_experiment(_spec("endure_rho2", "classic", 64, rhos=(2.0,)))
    n_models = len(NOMINAL_MODELS) + 1
    us_tune = (time.time() - t0) * 1e6 / (n_models * len(WIDX))

    rows: List[Row] = []
    for k, widx in enumerate(WIDX):
        w = EXPECTED_WORKLOADS[widx]
        B = axis.bench_set
        kls = np.asarray([float(kl_divergence(jnp.asarray(x),
                                              jnp.asarray(w)))
                          for x in B])
        def binned(costs):
            return [float(costs[(kls >= lo) & (kls < hi)].mean())
                    for lo, hi in BINS]

        curves = {name: binned(axis.design_bench_costs[space][(k, None)])
                  for name, space, _ in NOMINAL_MODELS}
        curves["endure_rho2"] = binned(robust.bench_costs[(k, 2.0)])

        # degradation = cost at far drift / cost near expected
        degr = {k2: v[-1] / v[0] for k2, v in curves.items()}
        flex_near = min(curves["klsm"][0], curves["fluid"][0])
        robust_flattest = degr["endure_rho2"] <= min(
            v for k2, v in degr.items() if k2 != "endure_rho2") * 1.05
        robust_best_far = curves["endure_rho2"][-1] <= min(
            v[-1] for k2, v in curves.items() if k2 != "endure_rho2") * 1.05
        rows.append(Row(
            f"fig19_flex_vs_robust_w{widx}", us_tune,
            cost_near_klsm=round(curves["klsm"][0], 3),
            cost_near_endure=round(curves["endure_rho2"][0], 3),
            cost_far_klsm=round(curves["klsm"][-1], 3),
            cost_far_endure=round(curves["endure_rho2"][-1], 3),
            claim_flexible_wins_near=flex_near <= curves["endure_rho2"][0]
            * 1.02,
            claim_robust_flattest=robust_flattest,
            claim_robust_best_under_drift=robust_best_far,
            degradation={k2: round(v, 2) for k2, v in degr.items()},
        ))
    return rows
