"""Paper Figure 19 + Section 10: flexibility does NOT imply robustness.

For w7 and w11, obtain nominal tunings from every design (incl. K-LSM,
Fluid, Lazy Leveling, Dostoevsky) and ENDURE's robust tuning (rho=2), then
evaluate C(w_hat, Phi) as the observed workload drifts away (binned by
KL-divergence).

Claims: flexible designs win at KL ~ 0 (Fig 4 regime) but degrade like the
classic nominal tunings under drift; only the robust tuning stays flat —
robustness comes from the tuning process, not the design."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import (EXPECTED_WORKLOADS, DesignSpace, kl_divergence,
                        tune_nominal, tune_robust)
from .common import B_SET, SYS, Row, costs_over_B

MODELS = [
    ("nominal_classic", lambda w: tune_nominal(w, SYS, seed=0)),
    ("lazy_leveling", lambda w: tune_nominal(w, SYS,
                                             DesignSpace.LAZY_LEVELING,
                                             seed=0)),
    ("dostoevsky", lambda w: tune_nominal(w, SYS, DesignSpace.DOSTOEVSKY,
                                          seed=0)),
    ("fluid", lambda w: tune_nominal(w, SYS, DesignSpace.FLUID, seed=0)),
    ("klsm", lambda w: tune_nominal(w, SYS, DesignSpace.KLSM,
                                    n_starts=192, seed=0)),
    ("endure_rho2", lambda w: tune_robust(w, 2.0, SYS, seed=0)),
]
BINS = [(0.0, 0.2), (0.5, 1.0), (2.0, 6.0)]


def run() -> List[Row]:
    import jax.numpy as jnp
    rows: List[Row] = []
    for widx in (7, 11):
        w = EXPECTED_WORKLOADS[widx]
        kls = np.asarray([float(kl_divergence(jnp.asarray(x),
                                              jnp.asarray(w)))
                          for x in B_SET])
        t0 = time.time()
        curves = {}
        for name, tuner in MODELS:
            costs = costs_over_B(tuner(w).phi)
            curves[name] = [float(costs[(kls >= lo) & (kls < hi)].mean())
                            for lo, hi in BINS]
        us = (time.time() - t0) * 1e6 / len(MODELS)

        # degradation = cost at far drift / cost near expected
        degr = {k: v[-1] / v[0] for k, v in curves.items()}
        flex_near = min(curves["klsm"][0], curves["fluid"][0])
        robust_flattest = degr["endure_rho2"] <= min(
            v for k, v in degr.items() if k != "endure_rho2") * 1.05
        robust_best_far = curves["endure_rho2"][-1] <= min(
            v[-1] for k, v in curves.items() if k != "endure_rho2") * 1.05
        rows.append(Row(
            f"fig19_flex_vs_robust_w{widx}", us,
            cost_near_klsm=round(curves["klsm"][0], 3),
            cost_near_endure=round(curves["endure_rho2"][0], 3),
            cost_far_klsm=round(curves["klsm"][-1], 3),
            cost_far_endure=round(curves["endure_rho2"][-1], 3),
            claim_flexible_wins_near=flex_near <= curves["endure_rho2"][0]
            * 1.02,
            claim_robust_flattest=robust_flattest,
            claim_robust_best_under_drift=robust_best_far,
            degradation={k: round(v, 2) for k, v in degr.items()},
        ))
    return rows
