"""Paper Figure 19 + Section 10: flexibility does NOT imply robustness.

For w7 and w11, obtain nominal tunings from every design (incl. K-LSM,
Fluid, Lazy Leveling, Dostoevsky) and ENDURE's robust tuning (rho=2), then
evaluate C(w_hat, Phi) as the observed workload drifts away (binned by
KL-divergence).

Claims: flexible designs win at KL ~ 0 (Fig 4 regime) but degrade like the
classic nominal tunings under drift; only the robust tuning stays flat —
robustness comes from the tuning process, not the design.

Each design tunes *both* workloads in one batched dispatch (the design is a
static jit argument, so the per-design calls stay separate compilations)."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import (EXPECTED_WORKLOADS, DesignSpace, kl_divergence,
                        tune_nominal_many, tune_robust_many)
from .common import B_SET, SYS, Row, costs_over_B

WIDX = (7, 11)
NOMINAL_MODELS = [
    ("nominal_classic", DesignSpace.CLASSIC, 64),
    ("lazy_leveling", DesignSpace.LAZY_LEVELING, 64),
    ("dostoevsky", DesignSpace.DOSTOEVSKY, 64),
    ("fluid", DesignSpace.FLUID, 64),
    ("klsm", DesignSpace.KLSM, 192),
]
BINS = [(0.0, 0.2), (0.5, 1.0), (2.0, 6.0)]


def run() -> List[Row]:
    import jax.numpy as jnp
    W = EXPECTED_WORKLOADS[list(WIDX)]
    t0 = time.time()
    tunings = {}          # name -> [result for w7, result for w11]
    for name, design, n_starts in NOMINAL_MODELS:
        tunings[name] = tune_nominal_many(W, SYS, design, n_starts=n_starts,
                                          seed=0)
    rob = tune_robust_many(W, [2.0], SYS, seed=0)
    tunings["endure_rho2"] = [rob[0][0], rob[1][0]]
    us_tune = (time.time() - t0) * 1e6 / (len(tunings) * len(WIDX))

    rows: List[Row] = []
    for k, widx in enumerate(WIDX):
        w = EXPECTED_WORKLOADS[widx]
        kls = np.asarray([float(kl_divergence(jnp.asarray(x),
                                              jnp.asarray(w)))
                          for x in B_SET])
        curves = {}
        for name, results in tunings.items():
            costs = costs_over_B(results[k].phi)
            curves[name] = [float(costs[(kls >= lo) & (kls < hi)].mean())
                            for lo, hi in BINS]

        # degradation = cost at far drift / cost near expected
        degr = {k2: v[-1] / v[0] for k2, v in curves.items()}
        flex_near = min(curves["klsm"][0], curves["fluid"][0])
        robust_flattest = degr["endure_rho2"] <= min(
            v for k2, v in degr.items() if k2 != "endure_rho2") * 1.05
        robust_best_far = curves["endure_rho2"][-1] <= min(
            v[-1] for k2, v in curves.items() if k2 != "endure_rho2") * 1.05
        rows.append(Row(
            f"fig19_flex_vs_robust_w{widx}", us_tune,
            cost_near_klsm=round(curves["klsm"][0], 3),
            cost_near_endure=round(curves["endure_rho2"][0], 3),
            cost_far_klsm=round(curves["klsm"][-1], 3),
            cost_far_endure=round(curves["endure_rho2"][-1], 3),
            claim_flexible_wins_near=flex_near <= curves["endure_rho2"][0]
            * 1.02,
            claim_robust_flattest=robust_flattest,
            claim_robust_best_under_drift=robust_best_far,
            degradation={k2: round(v, 2) for k2, v in degr.items()},
        ))
    return rows
