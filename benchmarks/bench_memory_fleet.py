"""Fleet memory arbitration: one shared budget, divided where it pays.

Today's deployment gives every tenant an equal slice of the fleet's
memory (``bits_per_entry``), fixed at tune time.  The arbitration loop
(:mod:`repro.online.memory`, ``docs/memory.md``) scores marginal
cost-model benefit per byte per tenant, re-divides the shared budget
when the drift loop's KL triggers fire, and re-tunes the moved tenants —
this suite measures whether that actually buys fleet throughput on the
executable engine, as a paired comparison: a ``static`` fleet on the
equal split vs an ``arbitrated`` fleet on the same traffic (identical
key populations and session plans, drift-arm seed conventions).

Scenarios (2 tenants each, 50k keys x 8 segments x 500 queries):

* ``skew_flip`` — a write-heavy tenant (w4) next to a read-bimodal one;
  mid-run the write-heavy tenant flips read-heavy.  The initial division
  drains filter memory from the write-heavy tenant (filters buy reads
  continuously; the write cost only moves when ceil(L) steps), and the
  flip fires KL-triggered re-divisions that re-score the moved tenant.
* ``skew_gradual`` — the same skewed start, gradually rotating toward a
  trimodal read mix: the division must track a moving target.

Claims gated by ``--check`` (see ``CHECK_METRICS['memory']``): on every
scenario the arbitrated fleet's throughput >= the static split's, the
minimum fleet speedup stays up, and with ``enabled: false`` the
arbitrated fleet is *bit-identical* to the static one (the fixed-split
path is untouched when the feature is off).
"""

from __future__ import annotations

from typing import List

from repro.api import (DesignSpec, DriftSpec, ExperimentSpec, MemorySpec,
                       Row, WorkloadSpec, run_experiment)

N_KEYS = 50_000
SEGMENTS = 8
SEG_QUERIES = 500
KEY_SPACE = 2 ** 24
RANGE_FRACTION = 1e-3
BITS_PER_ENTRY = 6.0          # the equal split each tenant starts from

#: the fleet: a write-heavy tenant next to a read-bimodal one — maximal
#: skew in where marginal memory pays (see the modeling note in
#: docs/memory.md: filters buy read classes continuously, so the arbiter
#: drains the write-heavy tenant's share).
TENANTS = ((0.01, 0.01, 0.01, 0.97), (0.49, 0.49, 0.01, 0.01))

#: (drift kind, shared drift target).  The target is near the read
#: tenant's own mix, so under ``flip`` the read tenant's traffic barely
#: moves while the write tenant flips read-heavy — a single-tenant shift
#: the arbiter must answer with a re-division.
SCENARIOS = (
    ("skew_flip", (0.45, 0.45, 0.09, 0.01)),
    ("skew_gradual", (0.33, 0.33, 0.33, 0.01)),
)

SYSTEM = (("N", float(N_KEYS)), ("entry_bits", 64.0 * 8),
          ("page_bits", 4096.0 * 8), ("bits_per_entry", BITS_PER_ENTRY),
          ("min_buf_bits", 64.0 * 8 * 64), ("s_rq", 2e-5),
          ("max_T", 30.0))


def make_spec(kind: str, target, enabled: bool = True,
              n_keys: int = N_KEYS, segments: int = SEGMENTS,
              seg_queries: int = SEG_QUERIES) -> ExperimentSpec:
    drift_kind = "flip" if kind.endswith("flip") else "gradual"
    return ExperimentSpec(
        name=f"memory_{kind}",
        workload=WorkloadSpec(workloads=TENANTS, nominal=False,
                              rhos=(0.5,)),
        design=DesignSpec(seed=0),
        drift=DriftSpec(kind=drift_kind, segments=segments,
                        n_queries=seg_queries, target=tuple(target),
                        n_keys=n_keys, key_space=KEY_SPACE,
                        range_fraction=RANGE_FRACTION, key_seed=100,
                        arms=("static_robust",), estimator="window",
                        window=4, capacity=64, kl_threshold=0.2,
                        budget_slack=1.0, min_windows=2, cooldown=2,
                        retune_starts=32, retune_steps=200),
        memory=MemorySpec(enabled=enabled, floor_bits_per_entry=2.0,
                          quantum_bits_per_entry=1.0, min_windows=2,
                          cooldown=2),
        system=SYSTEM)


def _record_tuple(rec):
    return (rec.index, rec.avg_io_per_query, rec.queries, rec.windows,
            tuple(rec.observed_mix.tolist()))


def _disabled_identical() -> bool:
    """`enabled: false` must leave the fixed-split path bit-identical:
    both fleets of a disabled run produce the same per-segment records."""
    report = run_experiment(make_spec("skew_flip", SCENARIOS[0][1],
                                      enabled=False, n_keys=6_000,
                                      segments=3, seg_queries=200))
    if report.memory_events:
        return False
    for f in range(len(TENANTS)):
        static = report.memory[(f, "static")].records
        arb = report.memory[(f, "arbitrated")].records
        if [_record_tuple(r) for r in static] \
                != [_record_tuple(r) for r in arb]:
            return False
    return True


def run(n_keys: int = N_KEYS, segments: int = SEGMENTS,
        seg_queries: int = SEG_QUERIES) -> List[Row]:
    rows: List[Row] = []
    speedups = []
    ordered = []
    engine_s = tuning_s = 0.0
    for kind, target in SCENARIOS:
        report = run_experiment(make_spec(kind, target, n_keys=n_keys,
                                          segments=segments,
                                          seg_queries=seg_queries))
        tp_static = report.memory_fleet_throughput("static")
        tp_arb = report.memory_fleet_throughput("arbitrated")
        speedup = tp_arb / max(tp_static, 1e-9)
        speedups.append(speedup)
        ordered.append(tp_arb >= tp_static * 0.999)
        engine_s += report.walls["memory_s"]
        tuning_s += report.walls["tuning_s"]
        final_shares = report.memory_events[-1]["shares"] \
            if report.memory_events else []
        rows.append(Row(
            f"memory_{kind}", 0.0,
            tp_static=round(tp_static, 4),
            tp_arbitrated=round(tp_arb, 4),
            fleet_speedup=round(speedup, 4),
            divisions=len(report.memory_events),
            redivisions=len([e for e in report.memory_events
                             if e["segment"] >= 0]),
            final_shares=[round(s, 2) for s in final_shares],
            arbitrated_retunes=sum(
                report.memory[(f, "arbitrated")].retunes
                for f in range(len(TENANTS))),
            claim_arbitrated_ge_static=ordered[-1],
            segment_io_static=[
                round(r.avg_io_per_query, 3)
                for f in range(len(TENANTS))
                for r in report.memory[(f, "static")].records],
            segment_io_arbitrated=[
                round(r.avg_io_per_query, 3)
                for f in range(len(TENANTS))
                for r in report.memory[(f, "arbitrated")].records],
        ))
    disabled_ok = _disabled_identical()
    rows.append(Row(
        "memory_fleet", engine_s * 1e6,
        n_keys=n_keys, segments=segments, seg_queries=seg_queries,
        tenants=len(TENANTS), scenarios=len(SCENARIOS), fleets=2,
        total_bits_per_entry=len(TENANTS) * BITS_PER_ENTRY,
        tuning_s=round(tuning_s, 2), engine_s=round(engine_s, 2),
    ))
    rows.append(Row(
        "memory_summary", 0.0,
        fleet_speedup_min=round(min(speedups), 4),
        claim_arbitrated_ge_static=all(ordered),
        claim_disabled_identical=disabled_ok,
    ))
    return rows
