"""Paper Figure 10: sensitivity of tuning performance to entry size E.

Claim: for the mixed workload (w7) ENDURE beats nominal at every entry
size; for the read-heavy workload (w11) nominal is better at small E but
ENDURE wins as E grows (memory budget becomes a smaller fraction of data);
robust tuning matters most in memory-constrained regimes."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import EXPECTED_WORKLOADS, LSMSystem, tune_nominal, tune_robust
from .common import B_SET, Row, delta_tp

ENTRY_BITS = [128 * 8, 512 * 8, 1024 * 8, 4096 * 8, 8192 * 8]
RHO = 1.0


def run() -> List[Row]:
    from repro.core import cost_vector
    rows: List[Row] = []
    for widx in (7, 11):
        w = EXPECTED_WORKLOADS[widx]
        t0 = time.time()
        derived = {}
        gains = []
        for eb in ENTRY_BITS:
            sys_e = LSMSystem(entry_bits=float(eb))
            rn = tune_nominal(w, sys_e, seed=0)
            rr = tune_robust(w, RHO, sys_e, seed=0)
            cn = B_SET @ np.asarray(cost_vector(rn.phi, sys_e), np.float64)
            cr = B_SET @ np.asarray(cost_vector(rr.phi, sys_e), np.float64)
            gain = float(delta_tp(cn, cr).mean())
            gains.append(gain)
            derived[f"gain_E{eb // 8}B"] = round(gain, 3)
        us = (time.time() - t0) * 1e6 / len(ENTRY_BITS)
        if widx == 7:
            derived["claim_robust_wins_all_E"] = all(g > 0 for g in gains)
        else:
            derived["claim_gain_grows_with_E"] = gains[-1] > gains[0]
        rows.append(Row(f"fig10_entry_size_w{widx}", us, **derived))
    return rows
