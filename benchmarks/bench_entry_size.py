"""Paper Figure 10: sensitivity of tuning performance to entry size E.

Claim: for the mixed workload (w7) ENDURE beats nominal at every entry
size; for the read-heavy workload (w11) nominal is better at small E but
ENDURE wins as E grows (memory budget becomes a smaller fraction of data);
robust tuning matters most in memory-constrained regimes.

Per entry size (the LSMSystem is a static jit argument, so each E compiles
once) both workloads are tuned in a single batched dispatch — two calls per
E instead of four."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import (EXPECTED_WORKLOADS, LSMSystem, cost_vector,
                        tune_nominal_many, tune_robust_many)
from .common import B_SET, Row, delta_tp

ENTRY_BITS = [128 * 8, 512 * 8, 1024 * 8, 4096 * 8, 8192 * 8]
RHO = 1.0
WIDX = (7, 11)


def run() -> List[Row]:
    t0 = time.time()
    W = EXPECTED_WORKLOADS[list(WIDX)]
    gains = {widx: {} for widx in WIDX}
    for eb in ENTRY_BITS:
        sys_e = LSMSystem(entry_bits=float(eb))
        nom = tune_nominal_many(W, sys_e, seed=0)
        rob = tune_robust_many(W, [RHO], sys_e, seed=0)
        for k, widx in enumerate(WIDX):
            cn = B_SET @ np.asarray(cost_vector(nom[k].phi, sys_e),
                                    np.float64)
            cr = B_SET @ np.asarray(cost_vector(rob[k][0].phi, sys_e),
                                    np.float64)
            gains[widx][eb] = float(delta_tp(cn, cr).mean())
    us = (time.time() - t0) * 1e6 / (len(ENTRY_BITS) * len(WIDX))

    rows: List[Row] = []
    for widx in WIDX:
        g = [gains[widx][eb] for eb in ENTRY_BITS]
        derived = {f"gain_E{eb // 8}B": round(gains[widx][eb], 3)
                   for eb in ENTRY_BITS}
        if widx == 7:
            derived["claim_robust_wins_all_E"] = all(x > 0 for x in g)
        else:
            derived["claim_gain_grows_with_E"] = g[-1] > g[0]
        rows.append(Row(f"fig10_entry_size_w{widx}", us, **derived))
    return rows
