"""Beyond-paper: ENDURE's robust-tuning paradigm applied to *mesh/layout
selection under uncertain serving mix*.

The paper's workload vector (z0, z1, q, w) maps 1:1 onto a serving fleet's
step mix (train, prefill, decode, long-context); the cost vector c(Phi)
comes from the dry-run roofline terms of each candidate layout.  The same
KL-ball dual (repro.core.robust.robust_cost) then picks the layout with the
best worst-case step time — a layout that stays good when the traffic mix
drifts (e.g. a long-context burst).

    PYTHONPATH=src python examples/robust_serving.py
"""

import numpy as np

from repro.core.robust_sharding import (LayoutCandidate, nominal_layout,
                                        robust_layout_sweep, worst_case_grid)


def main() -> None:
    # Candidate layouts for one pod (16x16): step-time vectors over the four
    # step classes (train, prefill, decode, long), in seconds.  These come
    # from dry-run roofline terms of the corresponding mesh/override combos
    # (see experiments/dryrun and EXPERIMENTS.md section Perf); a fleet
    # would regenerate them per model/hardware rev.
    candidates = [
        LayoutCandidate("tp16_fsdp16", np.array([17.8, 6.3, 0.9, 9.0])),
        # fastest training layout, but no SP path: 500k contexts thrash it
        LayoutCandidate("tp8_fsdp32", np.array([14.9, 5.1, 1.4, 40.0])),
        # slightly slower train, KV-sequence-parallel decode: flat tail
        LayoutCandidate("tp16_sp_decode", np.array([18.5, 6.6, 0.7, 1.1])),
        LayoutCandidate("tp4_fsdp64", np.array([16.2, 7.9, 2.8, 6.0])),
    ]

    expected_mix = np.array([0.70, 0.15, 0.14, 0.01])  # training-dominated

    nom = nominal_layout(candidates, expected_mix)
    print(f"nominal pick for expected mix: {nom.name} "
          f"(expected step {nom.expected_cost(expected_mix):.2f}s)")

    # A re-tuning storm: every rho re-evaluated in ONE batched dual grid
    # (vmap over candidates x rhos) instead of a per-rho robust_layout loop.
    rhos = (0.25, 1.0, 2.0)
    grid = worst_case_grid(candidates, expected_mix, rhos)
    nom_idx = next(i for i, c in enumerate(candidates) if c is nom)
    for j, rho in enumerate(rhos):
        best = int(np.argmin(grid[:, j]))
        print(f"rho={rho:4.2f}: robust pick = {candidates[best].name} "
              f"(worst-case step {grid[best, j]:.2f}s vs nominal's "
              f"{grid[nom_idx, j]:.2f}s)")

    # A long-context burst materializes:
    burst = np.array([0.30, 0.10, 0.20, 0.40])
    print("\nunder a long-context burst (40% long steps):")
    for c in candidates:
        print(f"  {c.name:16s} realized step {c.expected_cost(burst):.2f}s")
    rob = robust_layout_sweep(candidates, expected_mix, [1.0])[0]
    print(f"robust pick '{rob.name}' was "
          f"{'the' if rob.name == min(candidates, key=lambda c: c.expected_cost(burst)).name else 'near the'}"
          f" best layout for the burst — chosen before it happened.")


if __name__ == "__main__":
    main()
