"""Beyond-paper: a robust re-tuning storm as ONE declarative experiment.

The pre-facade version of this example hand-wired the pipeline (nominal
pick, per-rho dual grids, burst evaluation).  It is now a ~15-line
:class:`repro.api.ExperimentSpec`: an uncertain ZippyDB-like serving mix, a
rho storm (0.25 / 1 / 2), the compaction policy as a discrete arm tuned
jointly, and model scoring over a sampled benchmark set.  The spec is JSON
(``benchmarks/run.py --spec`` runs the same experiment with no code), and
the ``backend`` field scales it from this laptop (inline / single-device
fallback) to a device mesh (``sharded``) or a worker pool (``subprocess``)
unchanged.

    PYTHONPATH=src python examples/robust_serving.py
"""

from repro.api import (DesignSpec, ExperimentSpec, WorkloadSpec,
                       run_experiment)
from repro.core import zippydb_like

RHOS = (0.25, 1.0, 2.0)

SPEC = ExperimentSpec(
    name="serving_storm",
    workload=WorkloadSpec(workloads=(tuple(zippydb_like()),), rhos=RHOS,
                          nominal=True, bench_n=4000),
    design=DesignSpec(policies=("klsm", "lazy_leveling"), n_starts=32,
                      steps=150),
    backend="sharded",     # device-sharded sweep; inline on one device
)


def main() -> None:
    report = run_experiment(SPEC)
    nom = report.tuning((0, None))
    print(f"nominal pick for expected mix: {nom.describe(report.sys)} "
          f"policy={report.chosen[(0, None)]} "
          f"(expected cost {nom.cost:.3f})")
    for rho in RHOS:
        cell = (0, rho)
        rr = report.tuning(cell)
        d = report.delta_tp_vs_nominal(0, rho)
        print(f"rho={rho:4.2f}: robust pick {rr.describe(report.sys)} "
              f"policy={report.chosen[cell]} "
              f"(worst-case {rr.cost:.3f}; mean Delta-throughput vs nominal "
              f"over drifted mixes {d.mean():+.1%})")
    print("\nthe spec is data — save it and re-run with\n"
          "  python -m benchmarks.run --spec serving_storm.json:\n")
    print(SPEC.to_json())


if __name__ == "__main__":
    main()
