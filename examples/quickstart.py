"""Quickstart: tune an LSM tree nominally and robustly, then deploy both on
the executable engine and watch the robust tuning win under workload drift.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (LSMSystem, cost_vector, describe, kl_divergence,
                        rho_from_history, tune_nominal, tune_robust)
from repro.lsm import LSMTree, populate, run_session


def main() -> None:
    # 1. The workload you *expect*: read-heavy (ZippyDB-like).
    expected = np.array([0.33, 0.33, 0.33, 0.01])  # (z0, z1, q, w)

    # 2. Historical traces imply an uncertainty radius rho (Algorithm 1).
    history = np.array([
        [0.40, 0.30, 0.25, 0.05],
        [0.20, 0.35, 0.35, 0.10],
        [0.10, 0.20, 0.15, 0.55],   # ... including one write burst
    ])
    rho = rho_from_history(history)
    print(f"rho from history = {rho:.3f}")

    # 3. Tune.  (Paper defaults: 10B x 1KiB entries, 10 bits/entry memory.)
    sys_params = LSMSystem()
    nominal = tune_nominal(expected, sys_params, n_starts=32, steps=150)
    robust = tune_robust(expected, rho, sys_params, n_starts=32, steps=150)
    print(f"nominal tuning: {describe(nominal.phi, sys_params)} "
          f"expected C = {nominal.cost:.3f}")
    print(f"robust  tuning: {describe(robust.phi, sys_params)} "
          f"worst-case C = {robust.cost:.3f}")

    # 4. Model-predicted cost under the write burst the DBA feared:
    burst = np.array([0.05, 0.10, 0.05, 0.80])
    for name, r in [("nominal", nominal), ("robust", robust)]:
        c = float(burst @ np.asarray(cost_vector(r.phi, sys_params)))
        print(f"  {name}: model cost under write burst = {c:.3f}")

    # 5. Deploy both tunings on the real engine at reduced scale and
    #    execute the burst.  from_phi receives the SAME system the tuning
    #    was made under — it converts memory splits to bits-per-entry and
    #    re-scales them to the reduced key count.
    n = 20_000
    for name, r in [("nominal", nominal), ("robust", robust)]:
        tree = LSMTree.from_phi(r.phi, sys_params, expected_entries=n,
                                entry_bytes=64)
        keys = populate(tree, n, seed=1)
        res = run_session(tree, keys, burst, n_queries=3000, seed=2)
        print(f"  {name}: engine-measured I/O/query under burst "
              f"= {res.avg_io_per_query:.3f}")


if __name__ == "__main__":
    main()
