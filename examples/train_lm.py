"""End-to-end training driver: train a reduced LM for a few hundred steps on
CPU with checkpointing to the ENDURE-tuned store, then kill-and-resume to
demonstrate fault tolerance.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --arch mixtral-8x7b --steps 60
"""

import argparse
import shutil
import tempfile

import numpy as np

from repro.launch.train import TrainConfig, train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        # Phase 1: train, "crash" at 60% of the way.
        crash_at = max(2, int(args.steps * 0.6))
        print(f"=== phase 1: train to step {crash_at}, then 'crash' ===")
        out1 = train_loop(args.arch, reduced=True, steps=crash_at,
                          ckpt_dir=ckpt, seq_len=args.seq_len,
                          global_batch=args.global_batch,
                          tc=TrainConfig(ckpt_interval=10))
        # Phase 2: resume from the durable checkpoint + data cursor.
        print("=== phase 2: resume from checkpoint ===")
        out2 = train_loop(args.arch, reduced=True, steps=args.steps,
                          ckpt_dir=ckpt, resume=True, seq_len=args.seq_len,
                          global_batch=args.global_batch,
                          tc=TrainConfig(ckpt_interval=25))
        first = np.mean(out1["losses"][:10])
        last = np.mean(out2["losses"][-10:])
        print(f"loss: first-10 avg {first:.4f} -> last-10 avg {last:.4f} "
              f"({'improved' if last < first else 'NOT improved'})")
        st = out2["store"].manifest.stats
        print(f"manifest LSM engine: {st.queries['w']} puts, "
              f"{st.comp_pages_written} pages written "
              f"(shape: {out2['store'].manifest.shape()})")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
